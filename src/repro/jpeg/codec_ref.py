"""Sequential reference JPEG codec (numpy) — the bit-exact oracle.

The encoder produces standard baseline JFIF files (these are what the
device decoder consumes in tests/benchmarks); the decoder is a strict
sequential implementation of T.81 decoding used as ground truth for the
parallel decoder and for every Pallas kernel's ref.

Performance note: the encoder is vectorized per image (numpy); the decoder
is intentionally a straightforward sequential loop — it is the *oracle*,
not a baseline for speed (speed baselines are the jitted sequential-chain
decoders in repro.core).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import tables as T
from .format import (
    ComponentInfo,
    JpegImage,
    pack_bits_to_words,
    parse_jpeg,
    stuff_scan,
    unstuff_scan,
    write_jpeg,
)

# ---------------------------------------------------------------------------
# DCT operators
# ---------------------------------------------------------------------------

def dct_matrix() -> np.ndarray:
    """8x8 orthonormal DCT-II matrix C; fDCT: C @ X @ C.T, IDCT: C.T @ F @ C."""
    k = np.arange(8)[:, None]
    n = np.arange(8)[None, :]
    C = np.cos((2 * n + 1) * k * np.pi / 16) * np.sqrt(2.0 / 8.0)
    C[0] /= np.sqrt(2.0)
    return C


_C = dct_matrix()


def fdct_units(units: np.ndarray) -> np.ndarray:
    """Forward DCT of (N, 8, 8) level-shifted samples."""
    return np.einsum("ij,njk,lk->nil", _C, units, _C)


def idct_units(coeffs: np.ndarray) -> np.ndarray:
    """Inverse DCT of (N, 8, 8) dequantized coefficients."""
    return np.einsum("ji,njk,kl->nil", _C, coeffs, _C)


# ---------------------------------------------------------------------------
# Color space (JFIF / BT.601 full range)
# ---------------------------------------------------------------------------

def rgb_to_ycbcr(rgb: np.ndarray) -> np.ndarray:
    rgb = rgb.astype(np.float64)
    r, g, b = rgb[..., 0], rgb[..., 1], rgb[..., 2]
    y = 0.299 * r + 0.587 * g + 0.114 * b
    cb = -0.168735892 * r - 0.331264108 * g + 0.5 * b + 128.0
    cr = 0.5 * r - 0.418687589 * g - 0.081312411 * b + 128.0
    return np.stack([y, cb, cr], axis=-1)


def ycbcr_to_rgb(ycc: np.ndarray) -> np.ndarray:
    ycc = ycc.astype(np.float64)
    y, cb, cr = ycc[..., 0], ycc[..., 1] - 128.0, ycc[..., 2] - 128.0
    r = y + 1.402 * cr
    g = y - 0.344136286 * cb - 0.714136286 * cr
    b = y + 1.772 * cb
    out = np.stack([r, g, b], axis=-1)
    return np.clip(np.round(out), 0, 255).astype(np.uint8)


# ---------------------------------------------------------------------------
# Geometry helpers
# ---------------------------------------------------------------------------

SUBSAMPLING = {
    "4:4:4": ((1, 1), (1, 1), (1, 1)),
    "4:2:2": ((2, 1), (1, 1), (1, 1)),
    "4:2:0": ((2, 2), (1, 1), (1, 1)),
    "gray": ((1, 1),),
}


def _pad_edge(plane: np.ndarray, ph: int, pw: int) -> np.ndarray:
    h, w = plane.shape
    return np.pad(plane, ((0, ph - h), (0, pw - w)), mode="edge")


def _box_subsample(plane: np.ndarray, fh: int, fv: int) -> np.ndarray:
    """Box-average subsampling by integer factors (fh horizontal, fv vertical)."""
    if fh == 1 and fv == 1:
        return plane
    h, w = plane.shape
    return plane.reshape(h // fv, fv, w // fh, fh).mean(axis=(1, 3))


def _blocks_from_plane(plane: np.ndarray) -> np.ndarray:
    """(H, W) -> (H//8 * W//8, 8, 8) raster block order."""
    h, w = plane.shape
    return (
        plane.reshape(h // 8, 8, w // 8, 8).transpose(0, 2, 1, 3).reshape(-1, 8, 8)
    )


def _plane_from_blocks(blocks: np.ndarray, h: int, w: int) -> np.ndarray:
    return (
        blocks.reshape(h // 8, w // 8, 8, 8).transpose(0, 2, 1, 3).reshape(h, w)
    )


def scan_unit_layout(img: JpegImage) -> Dict[str, np.ndarray]:
    """Per-data-unit metadata in scan (interleaved MCU) order.

    Returns dict with (n_units,) arrays:
      comp      : component index of each unit
      block_idx : raster block index within that component's padded plane
    """
    ucomp = img.unit_component()
    upm = img.units_per_mcu
    n = img.n_units
    comp = np.tile(ucomp, img.n_mcus)
    block_idx = np.zeros(n, dtype=np.int64)
    # within-MCU unit offsets per component
    off_in_mcu = []
    for ci, c in enumerate(img.components):
        for i in range(c.v * c.h):
            off_in_mcu.append((ci, i))
    mcu_ids = np.repeat(np.arange(img.n_mcus, dtype=np.int64), upm)
    mx = mcu_ids % img.mcus_x
    my = mcu_ids // img.mcus_x
    unit_slot = np.tile(np.arange(upm), img.n_mcus)
    for s, (ci, i) in enumerate(off_in_mcu):
        sel = unit_slot == s
        c = img.components[ci]
        bx = mx[sel] * c.h + (i % c.h)
        by = my[sel] * c.v + (i // c.h)
        blocks_x = img.mcus_x * c.h
        block_idx[sel] = by * blocks_x + bx
    return {"comp": comp.astype(np.int32), "block_idx": block_idx}


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EncodeResult:
    jpeg_bytes: bytes
    image: JpegImage                 # parsed-back structure (convenience)
    coeff_zigzag: np.ndarray         # (n_units, 64) quantized, DC differential
    n_units: int


def encode_baseline(
    img: np.ndarray,
    quality: int = 90,
    subsampling: str = "4:2:0",
    restart_interval: int = 0,
    optimize_huffman: bool = False,
) -> EncodeResult:
    """Encode an (H, W, 3) uint8 RGB or (H, W) grayscale image."""
    if img.ndim == 2:
        subsampling = "gray"
    factors = SUBSAMPLING[subsampling]
    n_comp = len(factors)
    h_max = max(f[0] for f in factors)
    v_max = max(f[1] for f in factors)
    H, W = img.shape[:2]
    mcu_h, mcu_w = 8 * v_max, 8 * h_max
    mcus_y, mcus_x = -(-H // mcu_h), -(-W // mcu_w)
    pH, pW = mcus_y * mcu_h, mcus_x * mcu_w

    # Component sample planes (padded).
    if n_comp == 1:
        planes = [_pad_edge(img.astype(np.float64), pH, pW)]
    else:
        ycc = rgb_to_ycbcr(img)
        planes = []
        for ci, (fh, fv) in enumerate(factors):
            p = _pad_edge(ycc[..., ci], pH, pW)
            planes.append(_box_subsample(p, h_max // fh, v_max // fv))

    qt_luma, qt_chroma = T.quant_tables_for_quality(quality)
    quant_tables = {0: qt_luma} if n_comp == 1 else {0: qt_luma, 1: qt_chroma}

    components = []
    for ci, (fh, fv) in enumerate(factors):
        qid = 0 if ci == 0 else 1
        components.append(
            ComponentInfo(comp_id=ci + 1, h=fh, v=fv, quant_id=qid,
                          dc_table=0 if ci == 0 else 1, ac_table=0 if ci == 0 else 1)
        )

    # Quantized coefficients per component, raster block order.
    comp_coeff = []
    for ci, plane in enumerate(planes):
        blocks = _blocks_from_plane(plane) - 128.0
        f = fdct_units(blocks)
        q = quant_tables[components[ci].quant_id].reshape(8, 8)
        quant = np.sign(f) * np.floor(np.abs(f) / q + 0.5)
        comp_coeff.append(quant.astype(np.int32))

    # Gather into scan order + zig-zag.
    tmp_img = JpegImage(
        width=W, height=H, components=components, quant_tables=quant_tables,
        huffman_specs={}, scan_data=b"", restart_interval=restart_interval,
    )
    layout = scan_unit_layout(tmp_img)
    n_units = tmp_img.n_units
    coeff = np.zeros((n_units, 64), dtype=np.int32)
    for ci in range(n_comp):
        sel = layout["comp"] == ci
        blocks = comp_coeff[ci][layout["block_idx"][sel]]
        coeff[sel] = blocks.reshape(-1, 64)[:, T.ZIGZAG]

    # DC differential per component (in scan order), with prediction reset at
    # restart-interval boundaries when enabled.
    coeff_diff = coeff.copy()
    coeff_diff[:, 0] = rediff_dc_for_restart(
        coeff[:, 0], layout["comp"], tmp_img.units_per_mcu, restart_interval, n_comp
    )

    # Huffman table selection.
    if optimize_huffman:
        specs = optimal_specs_for(coeff_diff, layout["comp"], n_comp)
    else:
        specs = {
            ("dc", 0): T.STD_SPECS[("dc", 0)],
            ("ac", 0): T.STD_SPECS[("ac", 0)],
        }
        if n_comp > 1:
            specs[("dc", 1)] = T.STD_SPECS[("dc", 1)]
            specs[("ac", 1)] = T.STD_SPECS[("ac", 1)]

    scan = encode_scan(coeff_diff, layout["comp"], components, specs,
                       restart_interval, tmp_img.units_per_mcu)

    jpeg = write_jpeg(W, H, components, quant_tables, specs, scan, restart_interval)
    return EncodeResult(jpeg, parse_jpeg(jpeg), coeff_diff, n_units)


def _symbol_stream(
    coeff: np.ndarray, comp: np.ndarray, components: List[ComponentInfo],
    codes: Dict[Tuple[str, int], Tuple[np.ndarray, np.ndarray]],
) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized (values, lengths) Huffman+magnitude field stream for the scan.

    Per unit emits: DC(code+mag), then per nonzero AC slot up to 3 ZRL codes +
    (run,size) code + mag, then optional EOB. Inactive slots have length 0.
    """
    n_units = coeff.shape[0]
    # --- DC ---------------------------------------------------------------
    dc = coeff[:, 0]
    dc_cat = T.magnitude_category(dc)
    dc_bits = T.ones_complement_bits(dc, dc_cat)
    dc_tbl = np.array([components[c].dc_table for c in comp])
    # per-unit code/len lookup
    dc_code = np.zeros(n_units, dtype=np.uint32)
    dc_len = np.zeros(n_units, dtype=np.int32)
    for tid in np.unique(dc_tbl):
        cvals, clens = codes[("dc", int(tid))]
        sel = dc_tbl == tid
        dc_code[sel] = cvals[dc_cat[sel]]
        dc_len[sel] = clens[dc_cat[sel]]
    # DC field = code then magnitude bits
    dc_val = (dc_code.astype(np.uint64) << dc_cat.astype(np.uint64)) | dc_bits.astype(
        np.uint64
    )
    dc_totlen = dc_len + dc_cat

    # --- AC ---------------------------------------------------------------
    ac = coeff[:, 1:]  # (n, 63)
    nz = ac != 0
    pos = np.broadcast_to(np.arange(1, 64), ac.shape)
    # previous nonzero position (0 for none) via cumulative max of pos*nz
    prev = np.maximum.accumulate(np.where(nz, pos, 0), axis=1)
    prev_shifted = np.concatenate([np.zeros((n_units, 1), np.int64), prev[:, :-1]], 1)
    run = np.where(nz, pos - prev_shifted - 1, 0)
    zrl_n = run // 16
    rem = run % 16
    ac_cat = T.magnitude_category(ac)
    ac_bits = T.ones_complement_bits(ac, ac_cat)
    ac_sym = (rem.astype(np.int64) << 4) | ac_cat.astype(np.int64)
    ac_tbl = np.array([components[c].ac_table for c in comp])

    ac_code = np.zeros_like(ac, dtype=np.uint32)
    ac_len = np.zeros_like(ac, dtype=np.int32)
    zrl_code = np.zeros(n_units, dtype=np.uint32)
    zrl_len = np.zeros(n_units, dtype=np.int32)
    eob_code = np.zeros(n_units, dtype=np.uint32)
    eob_len = np.zeros(n_units, dtype=np.int32)
    for tid in np.unique(ac_tbl):
        cvals, clens = codes[("ac", int(tid))]
        sel = ac_tbl == tid
        ac_code[sel] = cvals[ac_sym[sel]]
        ac_len[sel] = clens[ac_sym[sel]]
        zrl_code[sel] = cvals[0xF0]
        zrl_len[sel] = clens[0xF0]
        eob_code[sel] = cvals[0x00]
        eob_len[sel] = clens[0x00]
    ac_len = np.where(nz, ac_len, 0)
    ac_val = (ac_code.astype(np.uint64) << ac_cat.astype(np.uint64)) | ac_bits.astype(
        np.uint64
    )
    ac_totlen = np.where(nz, ac_len + ac_cat, 0)

    # EOB if last nonzero AC position < 63 (including all-zero AC).
    last_nz = prev[:, -1]
    need_eob = last_nz < 63
    eob_len = np.where(need_eob, eob_len, 0)

    # Slot layout per unit: [DC] + 63 * [zrl0, zrl1, zrl2, ac] + [EOB]
    S = 1 + 63 * 4 + 1
    vals = np.zeros((n_units, S), dtype=np.uint64)
    lens = np.zeros((n_units, S), dtype=np.int32)
    vals[:, 0] = dc_val
    lens[:, 0] = dc_totlen
    for zi in range(3):
        active = (zrl_n > zi) & nz
        vals[:, 1 + zi + np.arange(63) * 4] = np.where(
            active, zrl_code[:, None].astype(np.uint64), 0
        )
        lens[:, 1 + zi + np.arange(63) * 4] = np.where(active, zrl_len[:, None], 0)
    vals[:, 1 + 3 + np.arange(63) * 4] = ac_val
    lens[:, 1 + 3 + np.arange(63) * 4] = ac_totlen
    vals[:, -1] = eob_code.astype(np.uint64)
    lens[:, -1] = eob_len

    flat_v = vals.reshape(-1)
    flat_l = lens.reshape(-1)
    keep = flat_l > 0
    return flat_v[keep], flat_l[keep]


def pack_bitstream(vals: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Vectorized MSB-first bit packing -> uint8 array (1-padded to byte)."""
    lens = lens.astype(np.int64)
    offs = np.cumsum(lens) - lens
    total = int(offs[-1] + lens[-1]) if len(lens) else 0
    nbytes = (total + 7) // 8
    out = np.zeros(nbytes + 8, dtype=np.uint8)
    if len(lens):
        shift = (offs % 8).astype(np.uint64)
        # place value MSB-aligned at bit `shift` of a 64-bit window
        place = vals.astype(np.uint64) << (np.uint64(64) - shift - lens.astype(np.uint64))
        byte0 = (offs // 8).astype(np.int64)
        for k in range(5):
            np.add.at(out, byte0 + k, ((place >> np.uint64(56 - 8 * k)) & np.uint64(0xFF)).astype(np.uint8))
    # pad final partial byte with 1s
    if total % 8 != 0:
        out[nbytes - 1] |= (1 << (8 - total % 8)) - 1
    return out[:nbytes]


def encode_scan(
    coeff_diff: np.ndarray,
    comp: np.ndarray,
    components: List[ComponentInfo],
    specs: Dict[Tuple[str, int], T.HuffmanSpec],
    restart_interval: int,
    units_per_mcu: int,
) -> bytes:
    """Entropy-encode the (already differential) coefficient stream."""
    codes = {k: T.build_canonical_codes(s) for k, s in specs.items()}
    if restart_interval <= 0:
        vals, lens = _symbol_stream(coeff_diff, comp, components, codes)
        return stuff_scan(pack_bitstream(vals, lens))
    # Restart intervals: re-diff DC within each interval and byte-align.
    n_units = coeff_diff.shape[0]
    n_mcus = n_units // units_per_mcu
    out = bytearray()
    m = 0
    for start_mcu in range(0, n_mcus, restart_interval):
        end_mcu = min(start_mcu + restart_interval, n_mcus)
        sl = slice(start_mcu * units_per_mcu, end_mcu * units_per_mcu)
        chunk = coeff_diff[sl].copy()
        vals, lens = _symbol_stream(chunk, comp[sl], components, codes)
        out += stuff_scan(pack_bitstream(vals, lens))
        if end_mcu < n_mcus:
            out += bytes([0xFF, 0xD0 + (m % 8)])
            m += 1
    return bytes(out)


def rediff_dc_for_restart(
    coeff_abs_dc: np.ndarray, comp: np.ndarray, units_per_mcu: int,
    restart_interval: int, n_comp: int,
) -> np.ndarray:
    """DC differences with predictor reset at each restart interval."""
    n_units = len(coeff_abs_dc)
    out = np.zeros_like(coeff_abs_dc)
    interval_units = restart_interval * units_per_mcu if restart_interval else n_units
    for s in range(0, n_units, interval_units):
        e = min(s + interval_units, n_units)
        for ci in range(n_comp):
            sel = np.where(comp[s:e] == ci)[0] + s
            out[sel] = np.diff(coeff_abs_dc[sel], prepend=0)
    return out


def optimal_specs_for(
    coeff_diff: np.ndarray, comp: np.ndarray, n_comp: int
) -> Dict[Tuple[str, int], T.HuffmanSpec]:
    """Image-adaptive Huffman tables from symbol frequencies (Annex K.2)."""
    specs: Dict[Tuple[str, int], T.HuffmanSpec] = {}
    groups = [(0, [0])] if n_comp == 1 else [(0, [0]), (1, [1, 2])]
    for tid, comps in groups:
        sel = np.isin(comp, comps)
        sub = coeff_diff[sel]
        # DC frequencies
        dc_cat = T.magnitude_category(sub[:, 0])
        dc_freq = np.bincount(dc_cat, minlength=256).astype(np.int64)
        # AC frequencies
        ac = sub[:, 1:]
        nz = ac != 0
        pos = np.broadcast_to(np.arange(1, 64), ac.shape)
        prev = np.maximum.accumulate(np.where(nz, pos, 0), axis=1)
        prev_shifted = np.concatenate(
            [np.zeros((len(sub), 1), np.int64), prev[:, :-1]], 1
        )
        run = np.where(nz, pos - prev_shifted - 1, 0)
        zrl_n = (run // 16)[nz]
        rem = (run % 16)[nz]
        cat = T.magnitude_category(ac[nz])
        sym = rem * 16 + cat
        ac_freq = np.bincount(sym, minlength=256).astype(np.int64)
        ac_freq[0xF0] += int(zrl_n.sum())
        last_nz = prev[:, -1]
        ac_freq[0x00] += int((last_nz < 63).sum())
        specs[("dc", tid)] = T.spec_from_frequencies(dc_freq)
        specs[("ac", tid)] = T.spec_from_frequencies(ac_freq)
    return specs


# ---------------------------------------------------------------------------
# Sequential decoder (oracle)
# ---------------------------------------------------------------------------

class BitReader:
    """MSB-first bit reader over a clean (unstuffed) byte stream."""

    def __init__(self, data: np.ndarray):
        self.words = pack_bits_to_words(data)
        self.pos = 0  # bit position
        self.nbits = len(data) * 8

    def peek16(self) -> int:
        w = self.pos >> 5
        off = self.pos & 31
        hi = int(self.words[w])
        lo = int(self.words[w + 1])
        window = ((hi << 32) | lo) >> (48 - off)
        return window & 0xFFFF

    def take(self, n: int) -> int:
        w = self.pos >> 5
        off = self.pos & 31
        hi = int(self.words[w])
        lo = int(self.words[w + 1])
        window = ((hi << 32) | lo) & 0xFFFFFFFFFFFFFFFF
        val = (window >> (64 - off - n)) & ((1 << n) - 1) if n else 0
        self.pos += n
        return val


def decode_coefficients(img: JpegImage) -> np.ndarray:
    """Entropy-decode the scan to (n_units, 64) zig-zag coefficients.

    DC coefficients are the *differential* values (prediction not yet
    reversed), matching the raw entropy output of the parallel decoder. With
    restart markers, prediction resets per interval (handled by the caller
    via dc_prefix_sum with interval resets).
    """
    clean, rst_bits = unstuff_scan(img.scan_data)
    luts = {
        k: T.build_decode_lut(s, is_dc=(k[0] == "dc"))
        for k, s in img.huffman_specs.items()
    }
    ucomp = img.unit_component()
    upm = img.units_per_mcu
    n_units = img.n_units
    out = np.zeros((n_units, 64), dtype=np.int32)
    reader = BitReader(clean)
    del rst_bits  # interval boundaries are re-derived from byte alignment below
    for u in range(n_units):
        comp = img.components[ucomp[u % upm]]
        # DC
        dc_lut = luts[("dc", comp.dc_table)]
        entry = int(dc_lut[reader.peek16()])
        clen = entry & 0x1F
        size = (entry >> T.LUT_SIZE_SHIFT) & 0xF
        if clen == 0:
            raise ValueError(f"invalid DC code at bit {reader.pos}")
        reader.take(clen)
        bits = reader.take(size)
        out[u, 0] = int(T.extend_magnitude(np.array([bits]), np.array([size]))[0])
        # AC
        z = 1
        ac_lut = luts[("ac", comp.ac_table)]
        while z < 64:
            entry = int(ac_lut[reader.peek16()])
            clen = entry & 0x1F
            if clen == 0:
                raise ValueError(f"invalid AC code at bit {reader.pos}")
            size = (entry >> T.LUT_SIZE_SHIFT) & 0xF
            run = (entry >> T.LUT_RUN_SHIFT) & 0xF
            reader.take(clen)
            if entry & T.LUT_EOB_BIT:
                break
            if entry & T.LUT_ZRL_BIT:
                z += 16
                continue
            z += run
            bits = reader.take(size)
            if z > 63:
                raise ValueError("AC run overflows block")
            out[u, z] = int(
                T.extend_magnitude(np.array([bits]), np.array([size]))[0]
            )
            z += 1
        # Byte-align at restart boundaries.
        if img.restart_interval and (u + 1) % (img.restart_interval * upm) == 0:
            if reader.pos % 8:
                reader.take(8 - reader.pos % 8)
    return out


def undiff_dc(img: JpegImage, coeff: np.ndarray) -> np.ndarray:
    """Reverse DC prediction in place (returns copy)."""
    out = coeff.copy()
    layout = scan_unit_layout(img)
    upm = img.units_per_mcu
    interval_units = (
        img.restart_interval * upm if img.restart_interval else img.n_units
    )
    for s in range(0, img.n_units, interval_units):
        e = min(s + interval_units, img.n_units)
        for ci in range(len(img.components)):
            sel = np.where(layout["comp"][s:e] == ci)[0] + s
            out[sel, 0] = np.cumsum(coeff[sel, 0])
    return out


def coefficients_to_planes(img: JpegImage, coeff_abs: np.ndarray) -> List[np.ndarray]:
    """Dequantize + de-zigzag + IDCT + assemble padded component planes."""
    layout = scan_unit_layout(img)
    planes = []
    for ci, c in enumerate(img.components):
        sel = layout["comp"] == ci
        zz = coeff_abs[sel]
        nat = np.zeros_like(zz)
        nat[:, T.ZIGZAG] = zz
        q = img.quant_tables[c.quant_id].reshape(1, 64)
        deq = (nat * q).astype(np.float64).reshape(-1, 8, 8)
        pix = idct_units(deq) + 128.0
        ph, pw = img.comp_plane_shape(ci)
        blocks = np.zeros((ph // 8 * (pw // 8), 8, 8))
        blocks[layout["block_idx"][sel]] = pix
        planes.append(np.clip(np.round(_plane_from_blocks(blocks, ph, pw)), 0, 255))
    return planes


def upsample_and_color(img: JpegImage, planes: List[np.ndarray]) -> np.ndarray:
    """Replicate-upsample chroma, convert to RGB, crop to true size."""
    if len(planes) == 1:
        return planes[0][: img.height, : img.width].astype(np.uint8)
    full = []
    for ci, p in enumerate(planes):
        c = img.components[ci]
        fh, fv = img.h_max // c.h, img.v_max // c.v
        up = np.repeat(np.repeat(p, fv, axis=0), fh, axis=1)
        full.append(up[: img.mcus_y * img.mcu_height, : img.mcus_x * img.mcu_width])
    ycc = np.stack(full, axis=-1)
    rgb = ycbcr_to_rgb(ycc)
    return rgb[: img.height, : img.width]


def decode_baseline(data: bytes) -> np.ndarray:
    """Full sequential decode: bytes -> RGB (or grayscale) uint8 array."""
    img = parse_jpeg(data)
    coeff = decode_coefficients(img)
    coeff = undiff_dc(img, coeff)
    planes = coefficients_to_planes(img, coeff)
    return upsample_and_color(img, planes)
