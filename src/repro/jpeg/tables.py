"""JPEG coding tables: zig-zag order, quantization matrices, canonical Huffman.

Everything in this module is host-side (numpy) table *construction*; the
resulting arrays are shipped to the device by :mod:`repro.core.decode`.

References: ITU-T T.81 (the JPEG standard), Annex K for the example tables.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Zig-zag scan order
# ---------------------------------------------------------------------------

# ZIGZAG[k] = natural (row-major) index of the k-th coefficient in zig-zag order.
ZIGZAG = np.array(
    [
        0,  1,  8, 16,  9,  2,  3, 10,
        17, 24, 32, 25, 18, 11,  4,  5,
        12, 19, 26, 33, 40, 48, 41, 34,
        27, 20, 13,  6,  7, 14, 21, 28,
        35, 42, 49, 56, 57, 50, 43, 36,
        29, 22, 15, 23, 30, 37, 44, 51,
        58, 59, 52, 45, 38, 31, 39, 46,
        53, 60, 61, 54, 47, 55, 62, 63,
    ],
    dtype=np.int32,
)

# INV_ZIGZAG[n] = zig-zag position of natural index n.
INV_ZIGZAG = np.argsort(ZIGZAG).astype(np.int32)

# 64x64 permutation matrix P with (P @ v_zigzag) = v_natural.
ZIGZAG_PERM = np.zeros((64, 64), dtype=np.float64)
ZIGZAG_PERM[ZIGZAG, np.arange(64)] = 1.0

# ---------------------------------------------------------------------------
# Quantization tables (Annex K) and libjpeg-style quality scaling
# ---------------------------------------------------------------------------

# Natural (row-major) order.
STD_LUMA_QUANT = np.array(
    [
        16, 11, 10, 16, 24, 40, 51, 61,
        12, 12, 14, 19, 26, 58, 60, 55,
        14, 13, 16, 24, 40, 57, 69, 56,
        14, 17, 22, 29, 51, 87, 80, 62,
        18, 22, 37, 56, 68, 109, 103, 77,
        24, 35, 55, 64, 81, 104, 113, 92,
        49, 64, 78, 87, 103, 121, 120, 101,
        72, 92, 95, 98, 112, 100, 103, 99,
    ],
    dtype=np.int32,
)

STD_CHROMA_QUANT = np.array(
    [
        17, 18, 24, 47, 99, 99, 99, 99,
        18, 21, 26, 66, 99, 99, 99, 99,
        24, 26, 56, 99, 99, 99, 99, 99,
        47, 66, 99, 99, 99, 99, 99, 99,
        99, 99, 99, 99, 99, 99, 99, 99,
        99, 99, 99, 99, 99, 99, 99, 99,
        99, 99, 99, 99, 99, 99, 99, 99,
        99, 99, 99, 99, 99, 99, 99, 99,
    ],
    dtype=np.int32,
)


def quality_scaled_quant(base: np.ndarray, quality: int) -> np.ndarray:
    """libjpeg quality scaling of a base quantization table.

    quality in [1, 100]; 50 = base table, 100 = all ones (max quality).
    """
    quality = int(np.clip(quality, 1, 100))
    if quality < 50:
        scale = 5000 // quality
    else:
        scale = 200 - quality * 2
    q = (base.astype(np.int64) * scale + 50) // 100
    return np.clip(q, 1, 255).astype(np.int32)


def quant_tables_for_quality(quality: int) -> Tuple[np.ndarray, np.ndarray]:
    """(luma, chroma) quantization tables in natural order."""
    return (
        quality_scaled_quant(STD_LUMA_QUANT, quality),
        quality_scaled_quant(STD_CHROMA_QUANT, quality),
    )


# ---------------------------------------------------------------------------
# Huffman table specifications (Annex K defaults)
# ---------------------------------------------------------------------------
# A Huffman spec is (bits, vals):
#   bits[i]  = number of codes of length i+1 (i in 0..15)
#   vals     = symbols in increasing code order
# Symbols: DC tables -> size category (0..11); AC tables -> (run << 4) | size,
# with 0x00 = EOB and 0xF0 = ZRL.

STD_DC_LUMA_BITS = np.array([0, 1, 5, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0], np.int32)
STD_DC_LUMA_VALS = np.arange(12, dtype=np.int32)

STD_DC_CHROMA_BITS = np.array([0, 3, 1, 1, 1, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0], np.int32)
STD_DC_CHROMA_VALS = np.arange(12, dtype=np.int32)

STD_AC_LUMA_BITS = np.array(
    [0, 2, 1, 3, 3, 2, 4, 3, 5, 5, 4, 4, 0, 0, 1, 0x7D], np.int32
)
STD_AC_LUMA_VALS = np.array(
    # fmt: off
    [
        0x01, 0x02, 0x03, 0x00, 0x04, 0x11, 0x05, 0x12,
        0x21, 0x31, 0x41, 0x06, 0x13, 0x51, 0x61, 0x07,
        0x22, 0x71, 0x14, 0x32, 0x81, 0x91, 0xA1, 0x08,
        0x23, 0x42, 0xB1, 0xC1, 0x15, 0x52, 0xD1, 0xF0,
        0x24, 0x33, 0x62, 0x72, 0x82, 0x09, 0x0A, 0x16,
        0x17, 0x18, 0x19, 0x1A, 0x25, 0x26, 0x27, 0x28,
        0x29, 0x2A, 0x34, 0x35, 0x36, 0x37, 0x38, 0x39,
        0x3A, 0x43, 0x44, 0x45, 0x46, 0x47, 0x48, 0x49,
        0x4A, 0x53, 0x54, 0x55, 0x56, 0x57, 0x58, 0x59,
        0x5A, 0x63, 0x64, 0x65, 0x66, 0x67, 0x68, 0x69,
        0x6A, 0x73, 0x74, 0x75, 0x76, 0x77, 0x78, 0x79,
        0x7A, 0x83, 0x84, 0x85, 0x86, 0x87, 0x88, 0x89,
        0x8A, 0x92, 0x93, 0x94, 0x95, 0x96, 0x97, 0x98,
        0x99, 0x9A, 0xA2, 0xA3, 0xA4, 0xA5, 0xA6, 0xA7,
        0xA8, 0xA9, 0xAA, 0xB2, 0xB3, 0xB4, 0xB5, 0xB6,
        0xB7, 0xB8, 0xB9, 0xBA, 0xC2, 0xC3, 0xC4, 0xC5,
        0xC6, 0xC7, 0xC8, 0xC9, 0xCA, 0xD2, 0xD3, 0xD4,
        0xD5, 0xD6, 0xD7, 0xD8, 0xD9, 0xDA, 0xE1, 0xE2,
        0xE3, 0xE4, 0xE5, 0xE6, 0xE7, 0xE8, 0xE9, 0xEA,
        0xF1, 0xF2, 0xF3, 0xF4, 0xF5, 0xF6, 0xF7, 0xF8,
        0xF9, 0xFA,
    ],
    # fmt: on
    dtype=np.int32,
)

STD_AC_CHROMA_BITS = np.array(
    [0, 2, 1, 2, 4, 4, 3, 4, 7, 5, 4, 4, 0, 1, 2, 0x77], np.int32
)
STD_AC_CHROMA_VALS = np.array(
    # fmt: off
    [
        0x00, 0x01, 0x02, 0x03, 0x11, 0x04, 0x05, 0x21,
        0x31, 0x06, 0x12, 0x41, 0x51, 0x07, 0x61, 0x71,
        0x13, 0x22, 0x32, 0x81, 0x08, 0x14, 0x42, 0x91,
        0xA1, 0xB1, 0xC1, 0x09, 0x23, 0x33, 0x52, 0xF0,
        0x15, 0x62, 0x72, 0xD1, 0x0A, 0x16, 0x24, 0x34,
        0xE1, 0x25, 0xF1, 0x17, 0x18, 0x19, 0x1A, 0x26,
        0x27, 0x28, 0x29, 0x2A, 0x35, 0x36, 0x37, 0x38,
        0x39, 0x3A, 0x43, 0x44, 0x45, 0x46, 0x47, 0x48,
        0x49, 0x4A, 0x53, 0x54, 0x55, 0x56, 0x57, 0x58,
        0x59, 0x5A, 0x63, 0x64, 0x65, 0x66, 0x67, 0x68,
        0x69, 0x6A, 0x73, 0x74, 0x75, 0x76, 0x77, 0x78,
        0x79, 0x7A, 0x82, 0x83, 0x84, 0x85, 0x86, 0x87,
        0x88, 0x89, 0x8A, 0x92, 0x93, 0x94, 0x95, 0x96,
        0x97, 0x98, 0x99, 0x9A, 0xA2, 0xA3, 0xA4, 0xA5,
        0xA6, 0xA7, 0xA8, 0xA9, 0xAA, 0xB2, 0xB3, 0xB4,
        0xB5, 0xB6, 0xB7, 0xB8, 0xB9, 0xBA, 0xC2, 0xC3,
        0xC4, 0xC5, 0xC6, 0xC7, 0xC8, 0xC9, 0xCA, 0xD2,
        0xD3, 0xD4, 0xD5, 0xD6, 0xD7, 0xD8, 0xD9, 0xDA,
        0xE2, 0xE3, 0xE4, 0xE5, 0xE6, 0xE7, 0xE8, 0xE9,
        0xEA, 0xF2, 0xF3, 0xF4, 0xF5, 0xF6, 0xF7, 0xF8,
        0xF9, 0xFA,
    ],
    # fmt: on
    dtype=np.int32,
)


@dataclasses.dataclass(frozen=True, eq=False)
class HuffmanSpec:
    """(bits, vals) Huffman table specification as stored in a DHT segment.

    ``eq=False``: the ndarray fields make the generated ``__eq__`` /
    ``__hash__`` raise (or compare elementwise), so instances compare and
    hash by identity — content identity goes through :meth:`digest`,
    which is what the LUT cache keys on.
    """

    bits: np.ndarray  # (16,) int32, bits[i] = #codes of length i+1
    vals: np.ndarray  # (sum(bits),) int32 symbols

    def __post_init__(self):
        assert self.bits.shape == (16,)
        assert int(self.bits.sum()) == len(self.vals)
        # Kraft inequality must hold for a prefix code.
        kraft = sum(int(n) / (1 << (i + 1)) for i, n in enumerate(self.bits))
        assert kraft <= 1.0 + 1e-12, f"invalid Huffman spec (Kraft={kraft})"

    def digest(self) -> str:
        h = hashlib.sha1()
        h.update(self.bits.astype(np.int32).tobytes())
        h.update(self.vals.astype(np.int32).tobytes())
        return h.hexdigest()


STD_SPECS = {
    ("dc", 0): HuffmanSpec(STD_DC_LUMA_BITS, STD_DC_LUMA_VALS),
    ("ac", 0): HuffmanSpec(STD_AC_LUMA_BITS, STD_AC_LUMA_VALS),
    ("dc", 1): HuffmanSpec(STD_DC_CHROMA_BITS, STD_DC_CHROMA_VALS),
    ("ac", 1): HuffmanSpec(STD_AC_CHROMA_BITS, STD_AC_CHROMA_VALS),
}


# ---------------------------------------------------------------------------
# Canonical code construction (T.81 Annex C)
# ---------------------------------------------------------------------------

def build_canonical_codes(spec: HuffmanSpec) -> Tuple[np.ndarray, np.ndarray]:
    """Return (codes, lengths) indexed by *symbol value* (0..255).

    codes[sym] is the right-aligned canonical codeword for `sym`;
    lengths[sym] == 0 means the symbol is absent from the table.
    """
    codes = np.zeros(256, dtype=np.uint32)
    lengths = np.zeros(256, dtype=np.int32)
    code = 0
    k = 0
    for length in range(1, 17):
        for _ in range(int(spec.bits[length - 1])):
            sym = int(spec.vals[k])
            codes[sym] = code
            lengths[sym] = length
            code += 1
            k += 1
        code <<= 1
    return codes, lengths


# LUT entry packing (int32):
#   bits  0..4   : codeword length in bits (1..16); 0 => invalid window
#   bits  5..9   : size (number of magnitude bits following the codeword, 0..15)
#   bits 10..13  : run (number of zero coefficients preceding, 0..15)
#   bit  14      : is_eob
#   bit  15      : is_zrl
LUT_LEN_SHIFT = 0
LUT_SIZE_SHIFT = 5
LUT_RUN_SHIFT = 10
LUT_EOB_BIT = 1 << 14
LUT_ZRL_BIT = 1 << 15
LOOKAHEAD_BITS = 16


def pack_lut_entry(codelen: int, size: int, run: int, is_eob: bool, is_zrl: bool) -> int:
    v = (codelen << LUT_LEN_SHIFT) | (size << LUT_SIZE_SHIFT) | (run << LUT_RUN_SHIFT)
    if is_eob:
        v |= LUT_EOB_BIT
    if is_zrl:
        v |= LUT_ZRL_BIT
    return v


def build_decode_lut(spec: HuffmanSpec, is_dc: bool) -> np.ndarray:
    """Full 2^16-entry lookahead decode table.

    lut[w] for a 16-bit window w (MSB-aligned next bits of the stream) packs
    (codelen, size, run, eob, zrl) for the codeword at the head of w.
    Windows that do not start with any valid codeword get entry 0; the decoder
    treats codelen==0 as "skip one bit" (desynchronized garbage), which
    preserves forward progress during speculative decoding.
    """
    lut = np.zeros(1 << LOOKAHEAD_BITS, dtype=np.int32)
    code = 0
    k = 0
    for length in range(1, 17):
        n = int(spec.bits[length - 1])
        for _ in range(n):
            sym = int(spec.vals[k])
            if is_dc:
                size, run, eob, zrl = sym & 0xF, 0, False, False
                # DC size categories can reach 11 for 8-bit precision -> the
                # 4-bit LUT size field only holds 0..15, fine.
                assert sym <= 15, "DC category out of range"
            else:
                run, size = (sym >> 4) & 0xF, sym & 0xF
                eob = sym == 0x00
                zrl = sym == 0xF0
            entry = pack_lut_entry(length, size, run, eob, zrl)
            lo = code << (LOOKAHEAD_BITS - length)
            hi = (code + 1) << (LOOKAHEAD_BITS - length)
            lut[lo:hi] = entry
            code += 1
            k += 1
        code <<= 1
    return lut


def min_bits_per_zstep(specs: Sequence[HuffmanSpec]) -> int:
    """Lower bound on bits consumed per zig-zag step across the given tables.

    Used to bound the number of decode iterations per subsequence. A symbol
    consuming (codelen + size) bits advances the zig-zag index by run+1 (or
    more for EOB); the per-step cost is (codelen+size)/(run+1).
    """
    best = 32.0
    for spec in specs:
        codes, lengths = build_canonical_codes(spec)
        for sym in range(256):
            if lengths[sym] == 0:
                continue
            run, size = (sym >> 4) & 0xF, sym & 0xF
            if sym == 0x00:  # EOB advances up to 64
                step = (lengths[sym]) / 64.0
            else:
                step = (lengths[sym] + size) / (run + 1)
            best = min(best, step)
    return max(1, int(np.floor(best)))


# ---------------------------------------------------------------------------
# Optimal (image-adaptive) Huffman table generation — T.81 Annex K.2
# ---------------------------------------------------------------------------

def spec_from_frequencies(freqs: np.ndarray) -> HuffmanSpec:
    """Generate a JPEG-legal (<=16 bit) Huffman spec from symbol frequencies.

    Implements the standard's two-phase procedure: build an unconstrained
    Huffman code by repeated pairing (with the reserved all-ones codepoint
    trick via the +1 dummy symbol), then apply the Annex K.2 BITS adjustment
    to cap code lengths at 16.
    """
    freqs = np.asarray(freqs, dtype=np.int64).copy()
    assert freqs.shape == (256,)
    # Dummy symbol (index 256) with freq 1 reserves the all-ones codeword.
    freq = np.zeros(257, dtype=np.int64)
    freq[:256] = freqs
    freq[256] = 1
    others = np.full(257, -1, dtype=np.int64)
    codesize = np.zeros(257, dtype=np.int64)

    while True:
        present = np.where(freq > 0)[0]
        if len(present) <= 1:
            break
        # Find two least-frequent symbols (ties -> larger index first, per spec).
        order = sorted(present, key=lambda i: (freq[i], -i))
        v1, v2 = int(order[0]), int(order[1])
        if v1 > v2:  # spec: v1 is the larger-index of equal-freq pair
            v1, v2 = v2, v1
        freq[v1] += freq[v2]
        freq[v2] = 0
        codesize[v1] += 1
        while others[v1] >= 0:
            v1 = int(others[v1])
            codesize[v1] += 1
        others[v1] = v2
        codesize[v2] += 1
        while others[v2] >= 0:
            v2 = int(others[v2])
            codesize[v2] += 1

    bits = np.zeros(33, dtype=np.int64)
    for i in range(257):
        if codesize[i] > 0:
            bits[min(int(codesize[i]), 32)] += 1

    # Adjust BITS so no code exceeds 16 bits (Annex K.2 Figure K.3).
    i = 32
    while i > 16:
        while bits[i] > 0:
            j = i - 2
            while bits[j] == 0:
                j -= 1
            bits[i] -= 2
            bits[i - 1] += 1
            bits[j + 1] += 2
            bits[j] -= 1
        i -= 1
    # Remove the reserved codepoint (largest code).
    i = 16
    while bits[i] == 0:
        i -= 1
    bits[i] -= 1

    # Sort symbols by (codesize, symbol value) to produce VALS.
    syms = [s for s in range(256) if codesize[s] > 0]
    syms.sort(key=lambda s: (codesize[s], s))
    out_bits = bits[1:17].astype(np.int32)
    vals = np.array(syms, dtype=np.int32)
    assert int(out_bits.sum()) == len(vals)
    return HuffmanSpec(out_bits, vals)


# ---------------------------------------------------------------------------
# Magnitude category ("size") helpers
# ---------------------------------------------------------------------------

def magnitude_category(values: np.ndarray) -> np.ndarray:
    """JPEG size category: number of bits to represent |v| (0 for v == 0)."""
    a = np.abs(values.astype(np.int64))
    cat = np.zeros_like(a)
    nz = a > 0
    cat[nz] = np.floor(np.log2(a[nz])).astype(np.int64) + 1
    return cat.astype(np.int32)


def ones_complement_bits(values: np.ndarray, cats: np.ndarray) -> np.ndarray:
    """The `cat`-bit magnitude field for each value (T.81 F.1.2.1.1).

    Positive v -> v; negative v -> v + 2^cat - 1 (ones' complement).
    """
    v = values.astype(np.int64)
    out = np.where(v >= 0, v, v + (np.int64(1) << cats.astype(np.int64)) - 1)
    return out.astype(np.int64)


def extend_magnitude(bits: np.ndarray, cats: np.ndarray) -> np.ndarray:
    """Inverse of ones_complement_bits (T.81 F.2.2.1 EXTEND)."""
    b = bits.astype(np.int64)
    c = cats.astype(np.int64)
    half = np.where(c > 0, np.int64(1) << np.maximum(c - 1, 0), np.int64(1))
    out = np.where((c > 0) & (b < half), b - (np.int64(1) << c) + 1, b)
    return np.where(c == 0, 0, out)
