"""JPEG substrate: format parsing, coding tables, reference codec."""

from .format import JpegImage, parse_jpeg, write_jpeg  # noqa: F401
from .codec_ref import decode_baseline, encode_baseline  # noqa: F401
