"""JPEG substrate: format parsing, coding tables, reference codec."""

from .format import (JpegFormatError, JpegImage,  # noqa: F401
                     JpegTruncationError, parse_jpeg, write_jpeg)
from .codec_ref import decode_baseline, encode_baseline  # noqa: F401
